"""SLO classes — priority-aware batch formation, class-aware admission.

Covers the docs/slo.md contract at every layer:

  * MicroBatcher: preemption ordering (rt rides the first chunk), FIFO
    within a class, the starvation guard (an aged batch request beats a
    stream of fresh rt arrivals), and the class-aware ``pending_ahead``
    depth the admission model consumes.
  * AdmissionController/AsyncSpmvService: a tight-deadline rt request is
    admitted where the classless queue-wait model would have shed it.
  * SLOReport: per-class scorecards and fairness scored within classes.
  * ClusterRouter: solver-step-aware session placement (pure helper) and
    the mixed-class kill replay losing zero accepted requests.

Batcher-level tests run against a fake engine (no JAX): batch formation
order is a pure queueing property.
"""

import asyncio
import time

import numpy as np
import pytest

from repro.engine import MicroBatcher, SpmvEngine
from repro.serve import (
    CLASS_DEADLINE_DEFAULTS,
    CLASS_RATE_WEIGHTS,
    SLO_CLASSES,
    AdmissionController,
    AsyncSpmvService,
    RequestRejected,
    TenantConfig,
    WorkloadSpec,
    class_rank,
    class_rate_weight,
    default_deadline,
    generate_trace,
    replay_sync,
    tenant_configs,
)
from repro.serve.replay import _class_fairness, _jain

COLS = 6
ROWS = 4


class _FakeEngine:
    """Registry + multiply stand-in recording every batch it serves."""

    class _Entry:
        shape = (ROWS, COLS)

    class _Registry:
        def get(self, name):
            return _FakeEngine._Entry()

    def __init__(self):
        self.registry = self._Registry()
        self.batches = []  # list of (cols, B) arrays, in serve order

    def multiply(self, name, X, obs=None):
        X = np.asarray(X)
        self.batches.append(X.copy())
        return np.zeros((ROWS, X.shape[1]), np.float32)


def _vec(k: float) -> np.ndarray:
    return np.full(COLS, float(k), np.float32)


def _first_columns(engine: _FakeEngine):
    """The leading value of each served vector, flattened in serve order."""
    out = []
    for X in engine.batches:
        out.extend(X[0, :].tolist())
    return out


# ------------------------------------------------------------------ classes


def test_class_rank_and_validation():
    assert SLO_CLASSES == ("rt", "standard", "batch")
    assert [class_rank(c) for c in SLO_CLASSES] == [0, 1, 2]
    with pytest.raises(ValueError, match="unknown SLO class"):
        class_rank("premium")
    with pytest.raises(ValueError, match="unknown SLO class"):
        TenantConfig(priority="premium")
    assert TenantConfig().priority == "standard"


def test_tenant_configs_from_workload_spec():
    spec = WorkloadSpec(
        names=("reg",), tenants=("fast", "slow"),
        tenant_classes={"fast": "rt", "slow": "batch"},
    )
    cfgs = tenant_configs(spec, max_pending=128)
    assert cfgs["fast"].priority == "rt"
    assert cfgs["slow"].priority == "batch"
    assert all(c.max_pending == 128 for c in cfgs.values())
    with pytest.raises(ValueError, match="unknown tenant"):
        WorkloadSpec(names=("reg",), tenants=("a",),
                     tenant_classes={"ghost": "rt"})
    # adding tenant_classes must not perturb the generated trace
    base = WorkloadSpec(names=("reg",), tenants=("fast", "slow"),
                        n_requests=20, seed=7)
    classed = WorkloadSpec(names=("reg",), tenants=("fast", "slow"),
                           n_requests=20, seed=7,
                           tenant_classes={"fast": "rt"})
    assert generate_trace(base) == generate_trace(classed)


# ------------------------------------------------------------------ batcher


def test_rt_preempts_forming_batch():
    """Bulk work queued first, an rt arrival last: the rt vector must ride
    the FIRST max_batch chunk of the flush, displacing bulk to later
    chunks."""
    eng = _FakeEngine()
    mb = MicroBatcher(eng, max_batch=2, buckets=(1, 2), auto_flush=False,
                      promote_after_s=60.0)
    for k in range(4):  # batch-class backlog: values 0..3
        mb.submit("m", _vec(k), priority=class_rank("batch"), cls="batch")
    mb.submit("m", _vec(99), priority=class_rank("rt"), cls="rt")
    mb.flush("m")
    served = _first_columns(eng)
    assert served[0] == 99.0, served  # rt preempted the forming batch
    assert sorted(served[1:]) == [0.0, 1.0, 2.0, 3.0]
    assert mb.preemptions == 1
    assert mb.promotions == 0


def test_fifo_within_one_class():
    eng = _FakeEngine()
    mb = MicroBatcher(eng, max_batch=2, buckets=(1, 2), auto_flush=False)
    for k in range(5):
        mb.submit("m", _vec(k))  # all DEFAULT_RANK
    mb.flush("m")
    assert _first_columns(eng) == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert mb.preemptions == 0


def test_starvation_guard_promotes_aged_batch_request():
    """An aged batch request must eventually beat fresh rt arrivals: after
    enough promote_after_s intervals its effective rank reaches (then
    passes) rt, and the arrival-order tie-break favors the elder."""
    eng = _FakeEngine()
    mb = MicroBatcher(eng, max_batch=2, buckets=(1, 2), auto_flush=False,
                      promote_after_s=0.01)
    mb.submit("m", _vec(7), priority=class_rank("batch"), cls="batch")
    time.sleep(0.05)  # ages ~5 promotion intervals: rank 2 -> well past 0
    for k in range(3):  # a stream of fresh rt arrivals
        mb.submit("m", _vec(100 + k), priority=class_rank("rt"), cls="rt")
    mb.flush("m")
    served = _first_columns(eng)
    assert served[0] == 7.0, served  # the elder won
    assert served[1:] == [100.0, 101.0, 102.0]
    assert mb.promotions >= 1


def test_pending_ahead_counts_equal_or_higher_priority_only():
    eng = _FakeEngine()
    mb = MicroBatcher(eng, max_batch=8, buckets=(8,), auto_flush=False,
                      promote_after_s=60.0)
    for k in range(3):
        mb.submit("m", _vec(k), priority=class_rank("batch"), cls="batch")
    mb.submit("m", _vec(9), priority=class_rank("rt"), cls="rt")
    # an rt arrival waits only behind the one rt vector already queued
    assert mb.pending_ahead("m", class_rank("rt")) == 1
    # a standard arrival waits behind rt but jumps the batch backlog
    assert mb.pending_ahead("m", class_rank("standard")) == 1
    # a batch arrival waits behind everything
    assert mb.pending_ahead("m", class_rank("batch")) == 4
    assert mb.pending("m") == 4
    assert mb.pending_by_class("m") == {"batch": 3, "rt": 1}
    mb.flush("m")
    assert mb.pending_ahead("m", class_rank("batch")) == 0


def test_promote_after_s_validation():
    with pytest.raises(ValueError, match="promote_after_s"):
        MicroBatcher(_FakeEngine(), promote_after_s=0.0)


# --------------------------------------------- class-weighted token buckets


def test_class_rate_weights_and_deadline_defaults():
    assert set(CLASS_RATE_WEIGHTS) == set(SLO_CLASSES)
    assert set(CLASS_DEADLINE_DEFAULTS) == set(SLO_CLASSES)
    # urgency-ordered refill: rt > standard > batch
    assert class_rate_weight("rt") > class_rate_weight("standard") \
        > class_rate_weight("batch") > 0
    # only batch carries an implicit SLO; interactive classes state theirs
    assert default_deadline("batch") is not None and \
        default_deadline("batch") > 0
    assert default_deadline("rt") is None
    assert default_deadline("standard") is None
    for fn in (class_rate_weight, default_deadline):
        with pytest.raises(ValueError, match="unknown SLO class"):
            fn("premium")


def test_class_weighted_bucket_refill():
    """Same nominal rate_rps, three classes: the rt bucket refills twice as
    fast as standard and four times as fast as batch (injected clock)."""
    ctrl = AdmissionController()
    for cls in SLO_CLASSES:
        ctrl.configure(cls, TenantConfig(rate_rps=10.0, burst=1.0,
                                         priority=cls, max_pending=None))
    for cls in SLO_CLASSES:  # drain every bucket's single-token burst
        ctrl.admit(cls, now=0.0)
    # +50ms: rt (20 tok/s) has a full token back; standard (10/s) and
    # batch (5/s) are still short
    ctrl.admit("rt", now=0.05)
    for cls in ("standard", "batch"):
        with pytest.raises(RequestRejected) as ei:
            ctrl.admit(cls, now=0.05)
        assert ei.value.reason == "rate_limited"
    # +100ms: standard catches up; batch still half a token short
    ctrl.admit("standard", now=0.10)
    with pytest.raises(RequestRejected):
        ctrl.admit("batch", now=0.10)
    # +200ms: batch finally refills — half the standard rate
    ctrl.admit("batch", now=0.20)


def test_burst_capacity_is_not_class_scaled():
    """The class weight scales *refill*, not burst: how much a tenant may
    burst is a separate knob from how fast the budget replenishes."""
    ctrl = AdmissionController()
    rt = ctrl.configure("rt", TenantConfig(rate_rps=4.0, priority="rt"))
    std = ctrl.configure("std", TenantConfig(rate_rps=4.0))
    assert rt.bucket.rate == pytest.approx(8.0)  # 2x refill
    assert std.bucket.rate == pytest.approx(4.0)
    assert rt.bucket.burst == std.bucket.burst == pytest.approx(4.0)


# ------------------------------------------------- class-aware admission


def _classed_service(**kwargs) -> AsyncSpmvService:
    from repro.data.matrices import regular_matrix

    svc = AsyncSpmvService(SpmvEngine(cache_capacity=8), **kwargs)
    svc.register(None, "reg", regular_matrix(64, 96, 5, seed=1))
    return svc


def test_class_aware_queue_wait_admits_tight_rt_deadline():
    """Ten standard vectors deep, one service-time of deadline headroom:
    the classless wait model sheds (11 x estimate >> deadline), while an
    rt request — which preempts the backlog — is admitted and served."""
    svc = _classed_service(
        tenants={"fast": TenantConfig(priority="rt"),
                 "std": TenantConfig(priority="standard")},
        safety=1.0, max_batch=16, buckets=(16,),
    )
    svc._est["reg"] = 0.05  # a known service-time estimate
    deadline = 0.2  # covers (0+1) x est, not (10+1) x est

    async def main():
        x = np.ones(96, np.float32)
        for _ in range(10):  # standard-class backlog, parked for 5s
            svc.batcher.submit("reg", x, deadline_s=5.0,
                               priority=class_rank("standard"),
                               cls="standard")
        # the classless model: 10 equal-priority vectors ahead -> shed
        with pytest.raises(RequestRejected) as ei:
            await svc.multiply("std", "reg", x, deadline_s=deadline)
        assert ei.value.reason == "queue_wait_infeasible"
        # the class-aware model: rt sees zero vectors ahead -> admitted
        y = await svc.multiply("fast", "reg", x, deadline_s=deadline)
        assert y.shape == (64,)
        await svc.aclose()

    asyncio.run(main())
    snap = svc.admission.snapshot()
    assert snap["fast"]["priority"] == "rt"
    assert snap["fast"]["completed"] == 1
    assert snap["std"]["rejected"]["queue_wait_infeasible"] == 1
    shed = svc.metrics.counter("serve.shed", reason="queue_wait_infeasible",
                               cls="standard")
    assert shed.value == 1


def test_batch_class_default_deadline_sheds_hopeless_backlog():
    """A batch request with NO explicit deadline picks up the class default
    (30s), so queue-wait shedding fires under a backlog it could never
    clear; a standard request (no class default) is admitted as before."""
    svc = _classed_service(
        tenants={"bulk": TenantConfig(priority="batch"),
                 "std": TenantConfig(priority="standard")},
        safety=1.0, max_batch=8, buckets=(8,),
    )
    svc._est["reg"] = 5.0  # (10 ahead + 1) x 5s = 55s > batch's 30s default

    async def main():
        x = np.ones(96, np.float32)
        for _ in range(10):  # standard-class backlog the batch class waits on
            svc.batcher.submit("reg", x, deadline_s=5.0,
                               priority=class_rank("standard"),
                               cls="standard")
        with pytest.raises(RequestRejected) as ei:
            await svc.multiply("bulk", "reg", x)  # deadline_s omitted
        assert ei.value.reason == "queue_wait_infeasible"
        # standard keeps deadline None -> nothing to shed against
        y = await svc.multiply("std", "reg", x)
        assert y.shape == (64,)
        await svc.aclose()

    asyncio.run(main())
    snap = svc.admission.snapshot()
    assert snap["bulk"]["rejected"]["queue_wait_infeasible"] == 1
    assert snap["bulk"]["completed"] == 0
    assert snap["std"]["completed"] == 1


# ------------------------------------------------------- report & fairness


def test_fairness_scored_within_classes_not_across():
    vectors = {"a": 100.0, "b": 50.0, "c": 50.0}
    classes = {"a": "rt", "b": "batch", "c": "batch"}
    by_class, overall = _class_fairness(vectors, classes)
    # rt out-completing batch is policy, not unfairness: both classes are
    # internally even, so the report must say "fair"
    assert by_class == {"batch": 1.0, "rt": 1.0}
    assert overall == 1.0
    # the old cross-class score would have flagged exactly this as unfair
    assert _jain(list(vectors.values())) < 0.9
    # genuine unfairness WITHIN a class still shows
    by_class, overall = _class_fairness(
        {"a": 100.0, "b": 90.0, "c": 10.0}, classes)
    assert by_class["rt"] == 1.0
    assert by_class["batch"] < 0.7
    assert by_class["batch"] <= overall < 1.0
    # degenerate cases
    assert _class_fairness({}, {}) == ({}, 1.0)


def test_replay_reports_per_class_scorecard():
    spec = WorkloadSpec(
        names=("reg",), tenants=("fast", "slow"), n_requests=24, seed=3,
        rate_rps=2000.0, batch_mix={1: 1.0}, integer_values=True,
        tenant_classes={"fast": "rt", "slow": "batch"},
    )
    svc = _classed_service(tenants=tenant_configs(spec, max_pending=64))
    report = replay_sync(svc, generate_trace(spec), time_scale=0.0,
                         integer_values=True)
    assert report.lost == 0 and report.errors == 0
    assert set(report.per_class) == {"rt", "batch"}
    total = sum(d["completed"] for d in report.per_class.values())
    assert total == report.completed
    for cls, d in report.per_class.items():
        assert d["tenants"] == 1
        assert d["p99_ms"] >= d["p50_ms"] >= 0.0
        assert isinstance(d["reject_reasons"], dict)
    assert set(report.fairness_by_class) == {"rt", "batch"}
    assert all(0.0 < v <= 1.0 for v in report.fairness_by_class.values())
    assert 0.0 < report.fairness <= 1.0
    assert report.per_tenant["fast"]["class"] == "rt"
    assert report.per_tenant["slow"]["class"] == "batch"
    d = report.to_dict()
    assert d["per_class"]["rt"]["completed"] == \
        report.per_class["rt"]["completed"]
    assert "per_class" in d and "fairness_by_class" in d
    assert "[rt]" in report.describe()


# ------------------------------------------------------------------ cluster


def test_pick_session_worker_is_step_aware():
    from repro.cluster import ClusterRouter

    pick = ClusterRouter.pick_session_worker
    # least-loaded by in-flight steps, regardless of cursor
    assert pick(["w0", "w1"], {"w0": 500}, 0) == "w1"
    assert pick(["w0", "w1"], {"w0": 500}, 1) == "w1"
    assert pick(["w0", "w1", "w2"], {"w0": 100, "w1": 50, "w2": 800}, 0) \
        == "w1"
    # ties rotate with the round-robin cursor instead of pinning one worker
    assert pick(["w0", "w1"], {}, 0) == "w0"
    assert pick(["w0", "w1"], {}, 1) == "w1"
    with pytest.raises(ValueError):
        pick([], {}, 0)


@pytest.mark.slow
def test_cluster_mixed_class_kill_replay_loses_nothing():
    """The mixed-class failover guarantee: SIGKILL a worker mid-replay
    with rt and batch traffic interleaved — zero requests lost in EVERY
    class, classes forwarded on the wire, per-class accounting exact."""
    from repro.cluster import ClusterRouter
    from repro.cluster.replay import replay_cluster

    rng = np.random.default_rng(3)
    mats = {}
    for name in ("hot", "warm"):
        a = np.round(rng.standard_normal((48, 40)) * 2.0).astype(np.float32)
        a[np.abs(a) < 1] = 0.0
        mats[name] = a
    spec = WorkloadSpec(
        names=tuple(mats), tenants=("fast", "bulk"), n_requests=40, seed=11,
        rate_rps=500.0, integer_values=True, batch_mix={1: 0.8, 4: 0.2},
        tenant_classes={"fast": "rt", "bulk": "batch"},
    )
    trace = generate_trace(spec)
    with ClusterRouter(workers=2, connect_timeout=300.0) as router:
        for name, a in mats.items():
            router.register(name, a, replicas=2)
        report = replay_cluster(router, trace, mats, threads=2,
                                kill_after=8, kill_worker="w0",
                                classes=spec.tenant_classes)
        assert report.lost == 0, report.summary()
        assert report.bit_exact, report.summary()
        assert {s["reason"] for s in report.shed} <= {"worker_lost"}
        assert report.failovers >= 1
        # per-class accounting covers the whole trace, class by class
        per_trace = {}
        for req in trace:
            cls = spec.tenant_classes[req.tenant]
            per_trace[cls] = per_trace.get(cls, 0) + 1
        for cls, n in per_trace.items():
            d = report.per_class[cls]
            assert d["accepted"] + d["shed"] + d["mismatched"] == n
            assert d["mismatched"] == 0
        assert "per_class" in report.summary()
        assert "inflight_steps" in router.stats()
