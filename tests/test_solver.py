"""Iterative-solver tier — api ``iterate``, engine/serve ``solve``, replay.

Layers, mirroring the feature:

  * single-device parity (property-based via hypothesis where installed):
    ``iterate(steps=k)`` bit-identical to k host ``exe(x)`` calls for the
    linear combines — on arbitrary floats for ``plain`` (no combine
    arithmetic), on dyadic values for richardson/jacobi (XLA may contract
    their update into an FMA; bit-parity with the twice-rounding host loop
    is only a theorem when no rounding happens at all);
  * convergence regressions with **pinned iteration counts** (seeded
    fixtures + integer-exact residual thresholds make the counts
    machine-independent): CG on the SPD Laplacian, PageRank to tolerance;
  * failure paths: tol never reached, evicted plans, argument validation;
  * the per-solve vs per-multiply Telemetry split and the MicroBatcher's
    deadline-aware flush (direct unit tests — the accounting the serving
    estimators lean on);
  * the asyncio serve surface (one admission per session, deadline
    shedding against the per-iteration EWMA) and solver sessions flowing
    through workload/replay;
  * the multi-device parity grid in a hermetic subprocess
    (tests/_solver_runner.py, 4 forced fake devices);
  * cluster: a worker dying mid-session rejects that session (never a
    silent restart) while failover re-homes the matrix for later traffic.
"""
import asyncio
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import _solver_runner as sr
from repro.api import COMBINES, IterateResult, SparseMatrix
from repro.engine import MicroBatcher, SpmvEngine
from repro.engine.telemetry import RequestRecord, Telemetry

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(coro):
    return asyncio.run(coro)


# ----------------------------------------------------- single-device parity


def _exe(a, **plan_kw):
    return SparseMatrix.from_dense(a).plan(**plan_kw).compile()


@pytest.mark.parametrize("fmt", ["coo", "csr", "bcoo", "bcsr"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_iterate_plain_bit_identical(fmt, impl):
    a = sr.random_square(48, 0.15, seed=11, spectral_radius=1.2)
    exe = _exe(a, fmt=fmt, impl=impl)
    x0 = np.random.default_rng(1).standard_normal(48).astype(np.float32)
    xh = sr.host_loop(lambda v: exe(v), x0, 5, "plain")
    res = exe.iterate(x0, steps=5, combine="plain")
    assert isinstance(res, IterateResult)
    assert res.steps == 5 and np.array_equal(np.asarray(res.x), xh)


def test_iterate_property_parity_random_matrices():
    """Property sweep: random seeds/sizes/steps, plain combine bit-exact."""
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)",
    )
    given = hypothesis.given
    settings = hypothesis.settings
    st = hypothesis.strategies

    a_big = sr.random_square(56, 0.2, seed=0, spectral_radius=1.1)
    exe = _exe(a_big, fmt="coo")  # one compile; seeds vary the data flow

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 7))
    def prop(seed, k):
        x0 = np.random.default_rng(seed).standard_normal(56).astype(
            np.float32)
        xh = sr.host_loop(lambda v: exe(v), x0, k, "plain")
        res = exe.iterate(x0, steps=k, combine="plain")
        assert res.steps == k
        assert np.array_equal(np.asarray(res.x), xh)

    prop()


def test_iterate_linear_combines_bit_identical_dyadic():
    """Richardson/jacobi on dyadic values: every intermediate is exactly
    representable, so device FMA and host two-step rounding coincide."""
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)",
    )
    given = hypothesis.given
    settings = hypothesis.settings
    st = hypothesis.strategies

    rng = np.random.default_rng(7)
    a = ((rng.random((48, 48)) < 0.12) * rng.integers(-2, 3, (48, 48))
         + 4 * np.eye(48)).astype(np.float32)
    exe = _exe(a, fmt="csr")
    diag = np.diag(a).astype(np.float32)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 6),
           combine=st.sampled_from(["richardson", "jacobi"]))
    def prop(seed, k, combine):
        r = np.random.default_rng(seed)
        x0 = r.integers(-3, 4, 48).astype(np.float32)
        b = r.integers(-3, 4, 48).astype(np.float32)
        kw = dict(b=b, omega=0.25) if combine == "richardson" else \
            dict(b=b, diag=diag)
        xh = sr.host_loop(lambda v: exe(v), x0, k, combine, **kw)
        res = exe.iterate(x0, steps=k, combine=combine, **kw)
        assert np.array_equal(np.asarray(res.x), xh)

    prop()


def test_iterate_callable_combine_escape_hatch():
    a = sr.random_square(32, 0.2, seed=2, spectral_radius=1.0)
    exe = _exe(a, fmt="coo")
    x0 = np.random.default_rng(3).standard_normal(32).astype(np.float32)
    res = exe.iterate(x0, steps=4, combine=lambda x, y: 0.5 * (x + y))
    x = x0
    for _ in range(4):
        y = np.asarray(exe(x), np.float32)
        x = (np.float32(0.5) * (x + y)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(res.x), x, rtol=1e-6, atol=1e-6)


def test_iterate_f64_when_supported():
    """f64 containers iterate bit-identically too — or the plan layer
    refuses them cleanly (x64 off is the JAX default; never silent."""
    a = sr.random_square(32, 0.2, seed=4, spectral_radius=1.1).astype(
        np.float64)
    try:
        exe = _exe(a, fmt="coo")
        x0 = np.random.default_rng(5).standard_normal(32)
        res = exe.iterate(x0.astype(a.dtype), steps=3, combine="plain")
    except (TypeError, ValueError) as e:
        pytest.skip(f"float64 containers unsupported here: {e}")
    x = x0.astype(np.asarray(exe(x0.astype(a.dtype))).dtype)
    for _ in range(3):
        x = np.asarray(exe(x))
    assert np.array_equal(np.asarray(res.x), x)


# --------------------------------------------------- convergence regressions


def test_cg_laplacian_pinned_iteration_count():
    """CG on the SPD 1D Laplacian: count matches the float64 reference CG
    exactly — and is pinned, so a solver change that costs iterations (a
    wrong beta, a stale residual) fails loudly."""
    n = 64
    a = sr.spd_laplacian(n)
    rng = np.random.default_rng(1)
    b = rng.integers(-2, 3, n).astype(np.float32)
    exe = _exe(a, fmt="csr")
    res = exe.iterate(np.zeros(n, np.float32), tol=1e-5, combine="cg",
                      b=b, max_steps=200, check_every=1)
    x_ref, iters_ref = sr.np_cg(a, b, np.zeros(n), 1e-5)
    assert res.converged and res.residual <= 1e-5
    assert res.steps == iters_ref == 11
    np.testing.assert_allclose(np.asarray(res.x, np.float64), x_ref,
                               atol=1e-4)


def test_pagerank_power_pinned_iteration_count():
    """Power iteration on the Google matrix of a seeded 32-node digraph:
    converges to the PageRank vector in a pinned number of steps (rounded
    up to the fori residual-check chunk)."""
    g = sr.pagerank_matrix(32, seed=5)
    exe = _exe(g, fmt="coo")
    x0 = np.full(32, 1.0 / 32, np.float32)
    res = exe.iterate(x0, tol=1e-6, combine="power", max_steps=100,
                      check_every=4)
    assert res.converged and res.residual <= 1e-6
    assert res.steps == 12  # damping 0.85 contracts fast; chunk-aligned
    assert res.steps % 4 == 0
    ref = sr.np_power(g, np.full(32, 1.0 / 32), 100)
    pr = np.asarray(res.x, np.float64)
    np.testing.assert_allclose(pr / pr.sum(), ref / ref.sum(), atol=1e-5)


def test_power_matches_numpy_reference_in_steps_mode():
    a = sr.random_square(40, 0.25, seed=9, spectral_radius=2.0)
    exe = _exe(a, fmt="coo")
    x0 = np.random.default_rng(2).standard_normal(40).astype(np.float32)
    res = exe.iterate(x0, steps=20, combine="power")
    ref = sr.np_power(a, x0, 20)
    np.testing.assert_allclose(np.asarray(res.x, np.float64), ref,
                               atol=1e-4)


# -------------------------------------------------------------- failure paths


def test_tol_never_reached_stops_at_max_steps():
    """A sign-flipping dominant eigenvalue keeps the power residual at ~2
    forever: the loop must stop at exactly max_steps with converged=False
    (never an infinite while_loop, never a rounded-up overshoot)."""
    a = (-np.eye(24)).astype(np.float32)
    exe = _exe(a, fmt="coo")
    x0 = np.random.default_rng(0).standard_normal(24).astype(np.float32)
    res = exe.iterate(x0, tol=1e-9, combine="power", max_steps=17,
                      check_every=5)
    assert not res.converged
    assert res.steps == 17  # the fori chunks must not overshoot max_steps
    assert res.residual > 1e-9


def test_engine_solve_on_evicted_plan_reactivates():
    eng = SpmvEngine(cache_capacity=1)
    a1 = sr.random_square(32, 0.2, seed=1, spectral_radius=1.0)
    a2 = sr.random_square(32, 0.2, seed=2, spectral_radius=1.0)
    eng.register("one", a1)
    eng.register("two", a2)  # evicts "one" from the plan cache
    x0 = np.random.default_rng(3).standard_normal(32).astype(np.float32)
    res = eng.solve("one", x0, steps=6, combine="power")
    ref = sr.np_power(a1, x0, 6)
    np.testing.assert_allclose(np.asarray(res.x, np.float64), ref, atol=1e-4)
    assert eng.registry.get("one").requests >= 6  # steps, not sessions


def test_iterate_argument_validation():
    a = sr.spd_laplacian(16)
    exe = _exe(a, fmt="coo")
    x0 = np.zeros(16, np.float32)
    with pytest.raises(ValueError):
        exe.iterate(x0)  # neither steps nor tol
    with pytest.raises(ValueError):
        exe.iterate(x0, steps=3, tol=1e-6)  # both
    with pytest.raises(ValueError):
        exe.iterate(np.zeros((16, 2), np.float32), steps=3)
    with pytest.raises((KeyError, ValueError)):
        exe.iterate(x0, steps=3, combine="not-a-combine")
    with pytest.raises(ValueError):
        exe.iterate(x0, steps=3, combine="cg")  # cg needs b
    with pytest.raises(ValueError):
        exe.iterate(x0, steps=3, combine="jacobi",
                    b=np.ones(16, np.float32))  # jacobi needs diag
    with pytest.raises(ValueError):
        exe.iterate(x0, steps=3, combine="jacobi",
                    b=np.ones(16, np.float32),
                    diag=np.zeros(16, np.float32))  # zero diagonal
    rect = SparseMatrix.from_dense(
        sr.random_square(16, 0.3, seed=0)[:8, :]).plan(fmt="coo").compile()
    with pytest.raises(ValueError):
        rect.iterate(np.zeros(16, np.float32), steps=2)  # not square
    assert set(COMBINES) >= {"plain", "power", "richardson", "jacobi", "cg"}


# ------------------------------------------- telemetry: solve vs multiply


def test_telemetry_last_is_multiply_only():
    """The accounting split the serving estimators depend on: last() never
    returns a solve session (a 200-step total masquerading as one multiply
    would shed every feasible request), last_solve() never a multiply."""
    t = Telemetry()
    mul = RequestRecord("m", 1, 0.0, 0.002, 0.0, True, False)
    slv = RequestRecord("m", 1, 0.0, 0.8, 0.0, True, False,
                        kind="solve", steps=200)
    t.record(mul)
    t.record(slv)
    assert t.last("m") is mul
    assert t.last_solve("m") is slv
    assert slv.per_iter_s == pytest.approx(0.8 / 200)
    assert mul.per_iter_s == pytest.approx(0.002)
    bd = t.breakdown("m")
    assert bd["requests"] == 2 and bd["solves"] == 1
    assert bd["solve_steps"] == 200
    t.clear()
    assert t.last("m") is None and t.last_solve("m") is None


def test_engine_solve_records_one_session():
    eng = SpmvEngine(cache_capacity=4)
    a = sr.random_square(32, 0.2, seed=6, spectral_radius=1.0)
    eng.register("m", a)
    x0 = np.random.default_rng(0).standard_normal(32).astype(np.float32)
    eng.multiply("m", x0)
    eng.solve("m", x0, steps=12, combine="power")
    recs = [r for r in eng.telemetry.records if r.kind == "solve"]
    assert len(recs) == 1 and recs[0].steps == 12
    assert eng.telemetry.last("m").kind == "multiply"
    assert eng.telemetry.last_solve("m").steps == 12
    # first session compiled its loop: flagged as a cold-start outlier
    assert recs[0].traced
    eng.solve("m", x0, steps=12, combine="power")
    assert not eng.telemetry.last_solve("m").traced


# ------------------------------------------- MicroBatcher deadline flush


def _small_engine():
    eng = SpmvEngine(cache_capacity=4)
    a = sr.random_square(24, 0.3, seed=8)
    eng.register("m", a)
    return eng, a


def test_batcher_deadline_flush_fires_without_full_queue():
    """Background mode: a sub-max_batch queue flushes when the oldest
    request's deadline arrives — not at max_delay_s, not never."""
    eng, a = _small_engine()
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(24).astype(np.float32) for _ in range(3)]
    with MicroBatcher(eng, max_batch=8, max_delay_s=30.0) as mb:
        t0 = time.monotonic()
        futs = [mb.submit("m", x, deadline_s=0.05) for x in xs]
        ys = [f.result(timeout=10.0) for f in futs]
        elapsed = time.monotonic() - t0
    assert elapsed < 5.0, "deadline flush waited for max_delay_s"
    assert mb.deadline_flushes >= 1
    assert mb.batches_run == 1  # coalesced, not flushed one by one
    for x, y in zip(xs, ys):
        np.testing.assert_allclose(y, a @ x, rtol=1e-4, atol=1e-4)


def test_batcher_urgent_request_pulls_queue_forward():
    """A later, tighter deadline must advance the whole queue's flush (the
    early request rides in the same coalesced SpMM)."""
    eng, a = _small_engine()
    rng = np.random.default_rng(1)
    x_slow = rng.standard_normal(24).astype(np.float32)
    x_fast = rng.standard_normal(24).astype(np.float32)
    with MicroBatcher(eng, max_batch=8, max_delay_s=30.0) as mb:
        f_slow = mb.submit("m", x_slow, deadline_s=30.0)
        f_fast = mb.submit("m", x_fast, deadline_s=0.05)
        y_slow = f_slow.result(timeout=10.0)  # resolves with the urgent one
        y_fast = f_fast.result(timeout=1.0)
    assert mb.batches_run == 1
    np.testing.assert_allclose(y_slow, a @ x_slow, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_fast, a @ x_fast, rtol=1e-4, atol=1e-4)


def test_batcher_failed_deadline_flush_rejects_futures():
    """A deadline flush whose engine call raises must reject the pending
    futures — a submitted request resolves, it never hangs."""
    eng, _ = _small_engine()
    with MicroBatcher(eng, max_batch=8, max_delay_s=30.0) as mb:
        fut = mb.submit("m", np.zeros(24, np.float32), deadline_s=0.05)
        eng.unregister("m")  # flush-time multiply now fails
        with pytest.raises(KeyError):
            fut.result(timeout=10.0)


def test_batcher_stop_drains_pending():
    eng, a = _small_engine()
    mb = MicroBatcher(eng, max_batch=8, max_delay_s=30.0, auto_flush=False)
    x = np.ones(24, np.float32)
    fut = mb.submit("m", x, deadline_s=30.0)
    mb.start()
    mb.stop(drain=True)
    np.testing.assert_allclose(fut.result(timeout=1.0), a @ x,
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- serve: solve()


def _solver_service(**kwargs):
    from repro.serve import AsyncSpmvService

    svc = AsyncSpmvService(SpmvEngine(cache_capacity=8), **kwargs)
    a = sr.random_square(48, 0.2, seed=3, spectral_radius=2.0)
    svc.register(None, "graph", a)
    return svc, a


def test_service_solve_matches_reference_and_charges_once():
    svc, a = _solver_service()
    admits = []
    inner = svc.admission.admit
    svc.admission.admit = lambda *aa, **kw: (admits.append(kw), inner(*aa, **kw))[1]

    async def main():
        async with svc:
            x0 = np.random.default_rng(0).standard_normal(48).astype(
                np.float32)
            res = await svc.solve("tenant-a", "graph", x0, steps=16,
                                  combine="power")
            ref = sr.np_power(a, x0, 16)
            np.testing.assert_allclose(np.asarray(res.x, np.float64), ref,
                                       atol=1e-4)
            assert res.steps == 16
            assert len(admits) == 1  # one session, ONE admission
            assert admits[0]["vectors"] == 1
            assert svc.admission.state("tenant-a").pending == 0

    run(main())


def test_service_solve_deadline_sheds_on_per_iter_ewma():
    from repro.serve import RequestRejected

    svc, _ = _solver_service()

    async def main():
        async with svc:
            x0 = np.random.default_rng(1).standard_normal(48).astype(
                np.float32)
            # two sessions: the first compiles (skipped as an outlier),
            # the second populates the per-iteration EWMA
            await svc.solve("tenant-a", "graph", x0, steps=8,
                            combine="power")
            await svc.solve("tenant-a", "graph", x0, steps=8,
                            combine="power")
            assert svc._solve_est.get("graph", 0.0) > 0.0
            with pytest.raises(RequestRejected) as exc:
                await svc.solve("tenant-a", "graph", x0, steps=1_000_000,
                                combine="power", deadline_s=1e-7)
            assert exc.value.reason == "deadline_infeasible"
            assert svc.admission.state("tenant-a").pending == 0
            # feasible sessions still pass after the rejection
            res = await svc.solve("tenant-a", "graph", x0, steps=4,
                                  combine="power")
            assert res.steps == 4

    run(main())


def test_service_solve_validates_x0_shape():
    svc, _ = _solver_service()

    async def main():
        async with svc:
            with pytest.raises(ValueError):
                await svc.solve("tenant-a", "graph",
                                np.zeros((48, 2), np.float32), steps=2)
            with pytest.raises(ValueError):
                await svc.solve("tenant-a", "graph",
                                np.zeros(47, np.float32), steps=2)

    run(main())


# ------------------------------------------------- workload/replay: solves


def test_workload_solver_sessions_are_deterministic():
    from repro.serve import WorkloadSpec, generate_trace

    spec = WorkloadSpec(names=("g",), n_requests=60, seed=5,
                        solve_frac=0.4, solve_steps=8)
    t1, t2 = generate_trace(spec), generate_trace(spec)
    assert t1 == t2
    solves = [r for r in t1 if r.is_solve]
    assert 0 < len(solves) < 60
    assert all(r.batch == 1 and r.solve_steps == 8 for r in solves)


def test_workload_solve_frac_zero_consumes_no_randomness():
    """The guarded draw: solve_frac=0 specs must generate traces identical
    to specs that never heard of solver fields — the determinism the perf
    gate's committed baselines replay against."""
    from repro.serve import WorkloadSpec, generate_trace

    base = WorkloadSpec(names=("g", "h"), n_requests=40, seed=9)
    touched = WorkloadSpec(names=("g", "h"), n_requests=40, seed=9,
                           solve_frac=0.0, solve_steps=99,
                           solve_combine="cg")
    assert generate_trace(base) == generate_trace(touched)
    assert not any(r.is_solve for r in generate_trace(base))


def test_replay_with_solver_sessions():
    from repro.serve import (AsyncSpmvService, WorkloadSpec, generate_trace,
                             replay)

    eng = SpmvEngine(cache_capacity=8)
    svc = AsyncSpmvService(eng)
    rng = np.random.default_rng(0)
    a = np.round(rng.standard_normal((48, 48)) * 2.0).astype(np.float32)
    a[np.abs(a) < 1] = 0.0
    svc.register(None, "g", a)
    spec = WorkloadSpec(names=("g",), n_requests=24, seed=7,
                        solve_frac=0.3, solve_steps=6, integer_values=True,
                        rate_rps=2000.0)
    trace = generate_trace(spec)
    n_solves = sum(r.is_solve for r in trace)
    assert n_solves > 0

    async def main():
        async with svc:
            return await replay(svc, trace, time_scale=0.0)

    rep = run(main())
    assert rep.lost == 0 and rep.errors == 0
    assert rep.solves == n_solves
    assert rep.solves_converged == 0  # steps-mode sessions: tol N/A -> 0
    assert rep.solve_iters["mean"] == pytest.approx(6.0)
    assert rep.solve_latency["p50_ms"] > 0.0
    assert rep.solve_per_iter_us > 0.0
    # solve latencies must NOT leak into the multiply percentiles
    assert rep.completed == len(trace)
    d = rep.to_dict()
    assert d["solves"] == n_solves and "solve_iters" in d


# --------------------------------------------- multi-device parity grid


@pytest.fixture(scope="module")
def solver_grid_output():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_solver_runner.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    if "SOLVER SKIP" in proc.stdout:
        pytest.skip("mesh solver tests need 4 (forced) devices")
    if proc.returncode != 0:
        pytest.fail(f"solver runner crashed:\n{proc.stderr[-3000:]}")
    return proc.stdout


def test_solver_grid_all_ok(solver_grid_output):
    assert "SOLVER DONE" in solver_grid_output
    assert "FAIL" not in solver_grid_output


@pytest.mark.parametrize("fmt", ["coo", "csr", "bcsr"])
@pytest.mark.parametrize("part", ["1d", "2d"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_solver_mesh_parity(solver_grid_output, fmt, part, impl):
    assert f"SOLVER parity {fmt}.{part}.{impl}: OK" in solver_grid_output


@pytest.mark.parametrize("cell", ["richardson.1d", "jacobi.2d"])
def test_solver_mesh_linear_combines(solver_grid_output, cell):
    assert f"SOLVER parity {cell}: OK" in solver_grid_output


def test_solver_mesh_tol_mode(solver_grid_output):
    assert "SOLVER tol mesh: OK" in solver_grid_output


# ------------------------------------------------------- cluster sessions


def test_cluster_solve_rejected_on_worker_loss_then_rehomed():
    """A solver session is atomic: SIGKILL its worker and the session is
    REJECTED (WorkerLostError — never silently restarted elsewhere), while
    failover re-homes the matrix so a knowing resubmit succeeds."""
    from repro.cluster import ClusterRouter
    from repro.cluster.protocol import WorkerLostError

    rng = np.random.default_rng(0)
    a = rng.integers(-2, 3, size=(24, 24)).astype(np.float32)
    x0 = rng.integers(-2, 3, size=24).astype(np.float32)
    ref = sr.np_power(a, x0, 6)
    with ClusterRouter(workers=2, connect_timeout=300.0) as router:
        router.register("g", a)
        res = router.solve("g", x0, steps=6, combine="power")
        assert res["steps"] == 6
        np.testing.assert_allclose(res["x"].astype(np.float64), ref,
                                   atol=1e-5)
        entry = router.entries["g"]
        victim = entry.placements[entry.rr % len(entry.placements)]
        router.kill_worker(victim)
        with pytest.raises(WorkerLostError):
            router.solve("g", x0, steps=4, combine="power")
        # failover re-homed the matrix: the resubmitted session succeeds
        res2 = router.solve("g", x0, steps=6, combine="power")
        np.testing.assert_allclose(res2["x"].astype(np.float64), ref,
                                   atol=1e-5)
        assert any(f["worker_id"] == victim for f in router.failovers)
        assert router.entries["g"].requests >= 12  # steps-weighted routing
