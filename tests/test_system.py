"""End-to-end behaviour: training converges, serving decodes, the public API
holds together (deliverable c, integration level)."""
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainLoop
from repro.optim import AdamWConfig


def test_training_learns_planted_structure():
    """The synthetic stream plants deterministic bigrams; 60 steps of the
    reduced model must cut loss markedly below the unigram entropy."""
    cfg = get_config("smollm-360m").reduced()
    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=10, total_steps=60)
    loop = TrainLoop(cfg, opt_cfg, make_local_mesh(), seq_len=64,
                     global_batch=8)
    loop.init_state()
    losses = loop.run(60, log_every=0)
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_serving_generates():
    from repro.launch.serve import Server

    cfg = get_config("qwen1.5-0.5b").reduced()
    server = Server(cfg, make_local_mesh(), max_len=24)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32)
    out = server.generate(prompts, 8)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_compressed_training_converges():
    cfg = get_config("smollm-360m").reduced()
    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=10, total_steps=40)
    loop = TrainLoop(cfg, opt_cfg, make_local_mesh(), seq_len=64,
                     global_batch=8, compress_pod_grads=True)
    loop.init_state()
    losses = loop.run(40, log_every=0)
    assert losses[-1] < losses[0] - 0.5
