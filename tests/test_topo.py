"""repro.topo — physical topology, axis assignments, collective cost model,
and topology-aware mesh placement.

Unit tier needs no devices (LinkSpec / AxisAssignment / DeviceTopology /
CollectiveCostModel are pure metadata + arithmetic; device grids are stood
in by plain ints).  The integration tier (build_mesh through repro.compat,
``SparseMatrix.plan(topology=)``, tuner overrule) runs on the 4 forced host
devices the tier-1 command provides and skips cleanly without them.
"""
import math

import numpy as np
import pytest

import jax

from repro.api import SparseMatrix
from repro.api.plan import fit_plan
from repro.core.adaptive import Plan
from repro.topo import (
    AxisAssignment,
    CollectiveCostModel,
    DeviceTopology,
    FakeTopology,
    LinkSpec,
    build_mesh,
    detect_topology,
)
from repro.topo.topology import HOST_LINK, ICI_LINK

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 (forced host) devices"
)


def _pim(devices=None) -> FakeTopology:
    return FakeTopology.pim_like((2, 2), devices=devices)


def _dense(shape, seed=0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(shape).astype(np.float32)
    a[np.abs(a) < 1.0] = 0.0
    return a


def _sm(shape, seed=0) -> SparseMatrix:
    return SparseMatrix.from_dense(_dense(shape, seed))


# ---------------------------------------------------------------- LinkSpec


def test_linkspec_validates():
    LinkSpec(bandwidth=1e9, latency=0.0)  # zero latency is legal
    with pytest.raises(ValueError, match="bandwidth"):
        LinkSpec(bandwidth=0.0, latency=1e-6)
    with pytest.raises(ValueError, match="bandwidth"):
        LinkSpec(bandwidth=1e9, latency=-1e-6)


# ---------------------------------------------------------- AxisAssignment


def test_axis_assignment_tag_group_and_dict_roundtrip():
    a = AxisAssignment(logical=("rows", "cols"),
                       physical=(("host",), ("bank",)))
    assert a.tag == "rows=host,cols=bank"
    assert a.group("cols") == ("bank",)
    with pytest.raises(KeyError, match="no logical axis"):
        a.group("parts")
    assert AxisAssignment.from_dict(a.to_dict()) == a
    assert hash(a) == hash(AxisAssignment.from_dict(a.to_dict()))


def test_axis_assignment_empty_group_and_arity():
    a = AxisAssignment(logical=("rows", "cols"),
                       physical=((), ("host", "bank")))
    assert a.tag == "rows=-,cols=host*bank"  # empty group renders as "-"
    with pytest.raises(ValueError, match="arity"):
        AxisAssignment(logical=("rows",), physical=(("a",), ("b",)))


# ---------------------------------------------------------- DeviceTopology


def test_topology_constructor_validation():
    ok = (ICI_LINK, ICI_LINK)
    with pytest.raises(ValueError, match="at least one"):
        DeviceTopology((), (), ())
    with pytest.raises(ValueError, match="duplicate"):
        DeviceTopology(("a", "a"), (2, 2), ok)
    with pytest.raises(ValueError, match="lengths differ"):
        DeviceTopology(("a", "b"), (2,), ok)
    with pytest.raises(ValueError, match=">= 1"):
        DeviceTopology(("a", "b"), (2, 0), ok)
    with pytest.raises(TypeError, match="LinkSpec"):
        DeviceTopology(("a", "b"), (2, 2), (ICI_LINK, 1e9))
    with pytest.raises(ValueError, match="devices"):
        DeviceTopology(("a", "b"), (2, 2), ok, devices=[0, 1, 2])


def test_topology_inspection():
    topo = _pim()
    assert topo.n_devices == 4
    assert topo.axis_size("bank") == 2
    assert topo.link("host").bandwidth == pytest.approx(1e6)
    with pytest.raises(KeyError, match="no physical axis"):
        topo.link("ring")
    assert topo.flat_devices() is None  # abstract until devices are bound
    assert "pim2x2" in repr(topo)


def test_assignments_pim_2x2():
    cands = _pim().assignments((2, 2), ("rows", "cols"))
    assert {a.tag for a in cands} == {
        "rows=host,cols=bank", "rows=bank,cols=host"
    }
    # size-1 logical axis takes the empty (free) group; the other axis
    # absorbs both physical axes in either order
    cands = _pim().assignments((1, 4), ("rows", "cols"))
    assert {a.tag for a in cands} == {
        "rows=-,cols=host*bank", "rows=-,cols=bank*host"
    }


def test_assignments_mismatch_and_arity():
    assert _pim().assignments((2, 1), ("rows", "cols")) == []  # product != 4
    assert _pim().assignments((8, 1), ("rows", "cols")) == []
    with pytest.raises(ValueError, match="arity"):
        _pim().assignments((2, 2), ("rows",))


def test_device_order_contiguous_trick():
    topo = _pim(devices=list(range(4)))  # grid [[0, 1], [2, 3]]
    straight, swapped = (
        AxisAssignment(("rows", "cols"), (("host",), ("bank",))),
        AxisAssignment(("rows", "cols"), (("bank",), ("host",))),
    )
    assert topo.device_order(straight) == [0, 1, 2, 3]
    # rows on bank means transposing the physical grid before flattening,
    # so each logical row's neighbours sit on the bank links
    assert topo.device_order(swapped) == [0, 2, 1, 3]


def test_device_order_abstract_topology_needs_devices():
    topo, a = _pim(), AxisAssignment(("rows", "cols"), (("bank",), ("host",)))
    with pytest.raises(ValueError, match="abstract"):
        topo.device_order(a)
    assert topo.device_order(a, devices=range(4)) == [0, 2, 1, 3]
    with pytest.raises(ValueError, match="devices"):
        topo.device_order(a, devices=[0, 1])


def test_fake_topology_defaults_and_pim_preset():
    topo = FakeTopology((2, 2))
    assert topo.axis_names == ("ax0", "ax1")
    assert all(l == ICI_LINK for l in topo.links)
    pim = _pim()
    assert pim.axis_names == ("host", "bank")
    assert pim.name == "pim2x2"
    assert pim.link("bank").bandwidth > pim.link("host").bandwidth * 100
    with pytest.raises(ValueError, match="2-axis"):
        FakeTopology.pim_like((2, 2, 2))


def test_detect_topology_cpu_fallback():
    topo = detect_topology(jax.devices())
    assert topo.axis_names == ("flat",)
    assert topo.axis_sizes == (jax.device_count(),)
    assert topo.links == (HOST_LINK,)
    assert topo.name.endswith(":flat")
    assert len(topo.flat_devices()) == jax.device_count()
    with pytest.raises(ValueError, match="no devices"):
        detect_topology([])


# ------------------------------------------------------ CollectiveCostModel


def test_group_cost_formula_and_free_groups():
    model = CollectiveCostModel(_pim())
    assert model.group_cost((), 1e9) == 0.0
    # single fast axis, n=2: b/2 / bw + 1 latency step
    b = 1000.0
    assert model.group_cost(("bank",), b) == pytest.approx(
        b * 0.5 / 1e9 + 1e-6
    )
    # a group spanning both axes is priced at the bottleneck bandwidth and
    # the worst latency: n=4 -> 2 tree steps
    assert model.group_cost(("host", "bank"), b) == pytest.approx(
        b * 0.75 / 1e6 + 2 * 50e-6
    )
    # size-1 physical axes are free
    slim = FakeTopology((1, 4), axis_names=("one", "many"))
    assert CollectiveCostModel(slim).group_cost(("one",), b) == 0.0


def test_traffic_split_by_crossing_axis():
    model = CollectiveCostModel(_pim())
    p2d = Plan("2d", "equally-sized", "coo", "psum_scatter", (2, 2), "t")
    t = model.traffic(p2d, (64, 128), 4)
    assert t["load"] == (0, math.ceil(128 / 2) * 4)      # x over rows axis
    assert t["merge"] == ((1,), math.ceil(64 / 2) * 8)   # y over cols axis
    # merge="global" all-reduces a full row buffer over BOTH axes
    t = model.traffic(Plan("2d", "equally-sized", "coo", "global", (2, 2),
                           "t"), (64, 128), 4)
    assert t["merge"] == ((0, 1), 64 * 8)
    # 1D: boundary ppermute is latency-only (zero merge bytes)
    t = model.traffic(Plan("1d", "nnz", "coo", "ppermute", (4, 1), "t"),
                      (64, 128), 4)
    assert t["load"] == (0, math.ceil(128 / 4) * 4)
    assert t["merge"] == ((0,), 0.0)


def test_rank_routes_heavy_direction_onto_fast_axis():
    model = CollectiveCostModel(_pim())
    plan = Plan("2d", "equally-sized", "coo", "psum_scatter", (2, 2), "t")
    # tall: merge (crossing cols) dominates -> cols must ride the bank axis
    ranked = model.rank(plan, (2048, 128), 4, ("rows", "cols"))
    assert [a.tag for a, _ in ranked] == [
        "rows=host,cols=bank", "rows=bank,cols=host"
    ]
    assert ranked[0][1]["total_s"] < ranked[-1][1]["total_s"]
    for _, price in ranked:
        assert price["total_s"] == pytest.approx(
            price["load_s"] + price["merge_s"]
        )
    # wide: the x broadcast (crossing rows) dominates -> opposite pick
    best = model.best(plan, (128, 2048), 4, ("rows", "cols"))
    assert best[0].tag == "rows=bank,cols=host"
    worst = model.worst(plan, (128, 2048), 4, ("rows", "cols"))
    assert worst[0].tag == "rows=host,cols=bank"
    # a grid the topology cannot lay out contiguously prices to nothing
    unfit = Plan("2d", "equally-sized", "coo", "psum", (8, 1), "t")
    assert model.rank(unfit, (64, 128), 4, ("rows", "cols")) == []


def test_rank_trims_1d_grid_to_its_single_axis():
    model = CollectiveCostModel(_pim())
    plan = Plan("1d", "nnz", "coo", "ppermute", (4, 1), "t")
    ranked = model.rank(plan, (64, 128), 4, ("parts", "ignored"))
    assert ranked
    for a, _ in ranked:
        assert a.logical == ("parts",)


def test_fit_plan_topology_prefers_cheap_grid_over_near_square():
    flat = DeviceTopology(("flat",), (4,), (HOST_LINK,), name="flat4")
    seed = Plan("2d", "equally-sized", "coo", "psum", (), "r")
    # near-square is the topology-blind default...
    assert fit_plan(seed, (64, 4096), 4, (8, 16)).grid == (2, 2)
    # ...but on one flat axis a wide matrix should put ALL devices on the
    # cols axis: R=1 makes the heavy x broadcast free (nothing to
    # replicate across a size-1 rows axis)
    fitted = fit_plan(seed, (64, 4096), 4, (8, 16), topology=flat)
    assert fitted.grid == (1, 4)


# ------------------------------------------------- build_mesh (integration)


@needs_mesh
def test_build_mesh_model_pick_follows_intensity():
    topo = _pim(devices=jax.devices()[:4])
    # the heavier logical axis lands on the fast bank links
    _, a = build_mesh(topo, (2, 2), intensity={"cols": 1e6, "rows": 1.0})
    assert a.tag == "rows=host,cols=bank"
    _, a = build_mesh(topo, (2, 2), intensity={"rows": 1e6, "cols": 1.0})
    assert a.tag == "rows=bank,cols=host"


@needs_mesh
def test_build_mesh_forced_assignment_and_dict_form():
    topo = _pim(devices=jax.devices()[:4])
    forced = AxisAssignment(("rows", "cols"), (("bank",), ("host",)))
    for spec in (forced, forced.to_dict()):
        mesh, a = build_mesh(topo, (2, 2), assignment=spec)
        assert a == forced
        assert [d.id for d in mesh.devices.flat] \
            == [d.id for d in topo.device_order(forced)]


@needs_mesh
def test_build_mesh_flat_fallback_when_shape_cannot_lay_out():
    topo = _pim(devices=jax.devices()[:4])
    mesh, a = build_mesh(topo, (2, 1))  # product 2 != 4: no contiguous layout
    assert a is None
    assert [d.id for d in mesh.devices.flat] \
        == [d.id for d in jax.devices()[:2]]


@needs_mesh
def test_build_mesh_abstract_topology_takes_devices():
    mesh, a = build_mesh(_pim(), (2, 2), devices=jax.devices()[:4])
    assert a is not None
    assert mesh.devices.size == 4
    with pytest.raises(ValueError, match="rank-3"):
        build_mesh(_pim(), (2, 2, 1), devices=jax.devices()[:4])


# ----------------------------------------------- api surface (integration)


@needs_mesh
def test_plan_topology_places_by_shape_and_keeps_values():
    topo = _pim(devices=jax.devices()[:4])
    rng, picks = np.random.default_rng(1), {}
    for name, shape in (("tall", (256, 32)), ("wide", (32, 256))):
        a = _dense(shape, seed=7)
        sm = SparseMatrix.from_dense(a)
        plan = sm.plan(scheme="2d.equally-sized", grid=(2, 2), topology=topo)
        assert plan.topo_assignment is not None
        assert plan.topo_assignment["topology"] == "pim2x2"
        assert plan.scheme_id.split("@", 1)[1] in (
            "rows=host,cols=bank", "rows=bank,cols=host"
        )
        assert "topo:" in plan.describe()
        assert plan.estimate["topo_load_s"] >= 0
        assert plan.estimate["topo_merge_s"] > 0
        picks[name] = tuple(map(tuple, plan.topo_assignment["physical"]))
        # placement changes where the bytes travel, never the values
        x = rng.standard_normal(shape[1]).astype(np.float32)
        y = np.asarray(plan.compile()(x))
        assert np.allclose(y, a @ x, rtol=1e-4, atol=1e-4)
    assert picks["tall"] != picks["wide"]  # opposite heavy directions


@needs_mesh
def test_plan_forced_assignment_reorders_the_mesh():
    topo = _pim(devices=jax.devices()[:4])
    sm = _sm((256, 32), seed=7)
    model = CollectiveCostModel(topo)
    auto = sm.plan(scheme="2d.equally-sized", grid=(2, 2), topology=topo)
    worst, _ = model.worst(auto.scheme, sm.shape, sm.dtype.itemsize,
                           auto.axes)
    forced = sm.plan(scheme="2d.equally-sized", grid=(2, 2), topology=topo,
                     assignment=worst)
    assert forced.scheme_id.endswith(f"@{worst.tag}")
    assert forced.scheme_id != auto.scheme_id
    assert [d.id for d in forced.mesh.devices.flat] \
        != [d.id for d in auto.mesh.devices.flat]
    with pytest.raises(ValueError, match="requires topology"):
        sm.plan(scheme="2d.equally-sized", grid=(2, 2), assignment=worst)


@needs_mesh
def test_tune_measurement_overrules_model_pick():
    from repro.tune import FakeMeasurer, Tuner

    topo = _pim(devices=jax.devices()[:4])
    sm = _sm((64, 128), seed=3)
    # first pass: discover which placed candidates the tuner measures
    scout = Tuner(measurer=FakeMeasurer(seed=1))
    scout.tune(sm, devices=topo.flat_devices(), topology=topo)
    placed = [c for c in scout.measurer.calls if "@rows=" in c]
    tags = {c.split("@", 1)[1].split("|", 1)[0] for c in placed}
    assert tags == {"rows=host,cols=bank", "rows=bank,cols=host"}
    # second pass: force one specific placement to be (fake-)fastest; the
    # measurement must overrule whatever the cost model would pick
    target = placed[-1]
    result = Tuner(measurer=FakeMeasurer(costs={target: 1e-9})).tune(
        sm, devices=topo.flat_devices(), topology=topo
    )
    scheme_id, impl = target.rsplit("|", 1)
    assert result.best.scheme_id == scheme_id
    assert result.best.impl == impl
    want_tag = scheme_id.split("@", 1)[1]
    got = result.best.topo_assignment
    assert AxisAssignment(
        tuple(got["logical"]), tuple(tuple(g) for g in got["physical"])
    ).tag == want_tag
