"""repro.tune — candidate generation, measurement, cache, scheme="tune".

The deterministic FakeMeasurer stands in for wall-clock timing everywhere
except the slow-marked end-to-end test, so the assertions here are exact:
the tuner's argmin, the cache's never-re-measure contract, and the engine's
margin-gated executor swap are all decidable without real timing noise.
"""

import json

import numpy as np
import pytest

from repro.api import SparseMatrix
from repro.core.adaptive import HardwareModel, enumerate_schemes
from repro.data.matrices import block_matrix, regular_matrix, scale_free_matrix
from repro.engine import SpmvEngine
from repro.tune import (
    CandidateGenerator,
    FakeMeasurer,
    Measurer,
    TuneKey,
    Tuner,
    TuningCache,
    make_key,
)

RNG = np.random.default_rng(0)


def _matrix(kind="regular"):
    if kind == "regular":
        return regular_matrix(96, 128, 5, seed=1)
    if kind == "scale-free":
        return scale_free_matrix(96, 128, 600, seed=2)
    return block_matrix(96, 128, block=(8, 16), block_density=0.2, seed=3)


# ----------------------------------------------------------- enumeration


def test_enumerate_schemes_analytic_pick_first():
    a = scale_free_matrix(512, 512, 6 * 512, seed=1)  # NNZ-r-std > 25
    stats = SparseMatrix.from_dense(a).stats
    hw = HardwareModel(chips=4)
    schemes = enumerate_schemes(stats, hw)
    assert schemes[0].partitioning == "1d"  # scale-free -> 1d.nnz (Obs. 5/18)
    assert schemes[0].scheme == "nnz"
    keys = [(p.partitioning, p.scheme, p.fmt, p.merge) for p in schemes]
    assert len(keys) == len(set(keys)), "duplicate candidates"


def test_candidate_generator_dedups_and_caps():
    sm = SparseMatrix.from_dense(_matrix("block"))
    gen = CandidateGenerator(max_candidates=3)
    plans = gen.plans(sm)
    assert 1 <= len(plans) <= 3
    ids = [(p.scheme_id, p.impl) for p in plans]
    assert len(ids) == len(set(ids))


def test_candidate_generator_block_matrix_tries_block_formats():
    sm = SparseMatrix.from_dense(_matrix("block"))
    fmts = {p.fmt for p in CandidateGenerator(max_candidates=16).plans(sm)}
    assert "bcoo" in fmts or "bcsr" in fmts


# ----------------------------------------------------------- measurement


def test_fake_measurer_is_deterministic_and_cost_driven():
    sm = SparseMatrix.from_dense(_matrix())
    plan = sm.plan(scheme="1d.nnz")
    a = FakeMeasurer(seed=3).measure(plan).mean_s
    b = FakeMeasurer(seed=3).measure(plan).mean_s
    c = FakeMeasurer(seed=4).measure(plan).mean_s
    assert a == b
    assert a != c
    forced = FakeMeasurer(costs={plan.scheme_id: 42.0}).measure(plan)
    assert forced.mean_s == 42.0


def test_real_measurer_single_device_runs_and_releases():
    sm = SparseMatrix.from_dense(_matrix())
    plan = sm.plan(scheme="1d.nnz")
    meas = Measurer(warmup=1, iters=2, trim=0)
    m = meas.measure(plan, meas.representative(sm))
    assert m.mean_s > 0
    assert len(m.times_s) == 2
    assert m.scheme_id == plan.scheme_id


# ----------------------------------------------------------- TuningCache


def test_tuning_cache_roundtrip(tmp_path):
    path = tmp_path / "tune.json"
    cache = TuningCache(path=path)
    key = TuneKey("fp0", "cpu:1", "float32", 1)
    record = {"scheme": {"partitioning": "1d"}, "impl": "xla", "mean_s": 1.0}
    cache.put(key, record)
    reloaded = TuningCache(path=path)
    assert reloaded.get(key) == record
    assert len(reloaded) == 1


def test_tuning_cache_key_isolation(tmp_path):
    cache = TuningCache(path=tmp_path / "tune.json")
    base = TuneKey("fp0", "cpu:1", "float32", 1)
    cache.put(base, {"mean_s": 1.0})
    assert cache.get(TuneKey("fp1", "cpu:1", "float32", 1)) is None
    assert cache.get(TuneKey("fp0", "cpu:8", "float32", 1)) is None
    assert cache.get(TuneKey("fp0", "cpu:1", "bfloat16", 1)) is None
    assert cache.get(TuneKey("fp0", "cpu:1", "float32", 32)) is None
    assert cache.get(TuneKey("fp0", "cpu:1", "float32", 1, "pallas")) is None
    assert (
        cache.get(TuneKey("fp0", "cpu:1", "float32", 1, "xla", (16, 16)))
        is None
    )
    assert cache.get(base) == {"mean_s": 1.0}


@pytest.mark.parametrize(
    "payload",
    [
        "not json at all {",
        '{"version": 999, "entries": {}}',
        '{"no_entries_key": true}',
        '{"version": 1, "entries": []}',
    ],
)
def test_tuning_cache_corrupt_file_recovers(tmp_path, payload):
    path = tmp_path / "tune.json"
    path.write_text(payload)
    cache = TuningCache(path=path)  # must not raise
    assert len(cache) == 0
    assert cache.load_error is not None
    key = TuneKey("fp0", "cpu:1", "float32", 1)
    cache.put(key, {"mean_s": 2.0})  # overwrites the corrupt file
    assert TuningCache(path=path).get(key) == {"mean_s": 2.0}
    assert json.loads(path.read_text())["version"] == 1


def test_make_key_folds_in_dtype_and_batch():
    sm32 = SparseMatrix.from_dense(_matrix())
    k1 = make_key(sm32)
    k2 = make_key(sm32, batch=8)
    assert k1 != k2
    assert k1.fingerprint == sm32.fingerprint()


# ----------------------------------------------------------- the tuner


def test_tune_measured_never_worse_than_analytic_pick():
    sm = SparseMatrix.from_dense(_matrix())
    tuner = Tuner(measurer=FakeMeasurer(seed=11))
    result = tuner.tune(sm)
    assert result.best_measurement.mean_s <= result.baseline.mean_s
    assert result.speedup >= 1.0
    plan = result.best
    assert plan.measured["mean_s"] <= plan.measured["baseline_mean_s"]
    assert "measured:" in plan.describe()


def test_scheme_tune_is_deterministic_under_seeded_fake_measurer():
    picks = []
    for _ in range(2):
        sm = SparseMatrix.from_dense(_matrix("scale-free"))
        tuner = Tuner(measurer=FakeMeasurer(seed=5))
        pln = sm.plan(scheme="tune", tuner=tuner)
        picks.append((pln.scheme_id, pln.impl, pln.grid))
    assert picks[0] == picks[1]


def test_scheme_tune_rejects_silent_overrides():
    sm = SparseMatrix.from_dense(_matrix())
    for kw in ({"fmt": "csr"}, {"partitioning": "2d"}, {"merge": "psum"},
               {"grid": (2, 2)}):
        with pytest.raises(ValueError, match="searches"):
            sm.plan(scheme="tune", tuner=Tuner(measurer=FakeMeasurer()), **kw)


def test_tuning_cache_expands_user_path(tmp_path, monkeypatch):
    monkeypatch.setenv("HOME", str(tmp_path))
    cache = TuningCache(path="~/tune-cache/w.json")
    key = TuneKey("fp0", "cpu:1", "float32", 1)
    cache.put(key, {"mean_s": 1.0})
    assert (tmp_path / "tune-cache" / "w.json").exists()
    assert TuningCache(path="~/tune-cache/w.json").get(key) == {"mean_s": 1.0}


def test_scheme_tune_respects_forced_costs():
    sm = SparseMatrix.from_dense(_matrix())
    costs = {"1d.nnz-rgrn.csr.ppermute": 1e-9}
    pln = sm.plan(scheme="tune", tuner=Tuner(measurer=FakeMeasurer(costs=costs)))
    assert pln.scheme_id == "1d.nnz-rgrn.csr.ppermute"


def test_tune_cache_hit_skips_measurement(tmp_path):
    a = _matrix()
    meas1 = FakeMeasurer(seed=1)
    cache_path = tmp_path / "winners.json"
    t1 = Tuner(measurer=meas1, cache=TuningCache(path=cache_path))
    r1 = t1.tune(SparseMatrix.from_dense(a))
    assert not r1.from_cache
    assert len(meas1.calls) >= 2

    meas2 = FakeMeasurer(seed=1)
    t2 = Tuner(measurer=meas2, cache=TuningCache(path=cache_path))
    r2 = t2.tune(SparseMatrix.from_dense(a))  # fresh process, same matrix
    assert r2.from_cache
    assert meas2.calls == []  # the whole point: zero re-measures
    assert r2.best.scheme_id == r1.best.scheme_id
    assert r2.best.measured["from_cache"]


def test_tune_cache_does_not_cross_impls(tmp_path):
    """An xla winner answers nothing about a pallas search: the second
    tune must re-measure its own candidates, not return the xla record."""
    a = _matrix()
    path = tmp_path / "w.json"

    def _tuner(impl):
        return Tuner(
            generator=CandidateGenerator(impls=(impl,)),
            measurer=FakeMeasurer(),
            cache=TuningCache(path=path),
        )

    r_xla = _tuner("xla").tune(SparseMatrix.from_dense(a))
    assert r_xla.best.impl == "xla"
    r = _tuner("pallas").tune(SparseMatrix.from_dense(a))
    assert not r.from_cache
    assert r.best.impl == "pallas"


def test_tune_cache_miss_on_different_matrix(tmp_path):
    cache = TuningCache(path=tmp_path / "winners.json")
    meas = FakeMeasurer()
    tuner = Tuner(measurer=meas, cache=cache)
    tuner.tune(SparseMatrix.from_dense(_matrix("regular")))
    n = len(meas.calls)
    r = tuner.tune(SparseMatrix.from_dense(_matrix("scale-free")))
    assert not r.from_cache
    assert len(meas.calls) > n


def test_tune_cache_hit_rebases_baseline_on_callers_incumbent(tmp_path):
    """A cache hit must answer the caller's margin question: result.baseline
    must describe the baseline= incumbent (from its recorded candidate
    timing), not whatever baseline the original run happened to record."""
    a = _matrix()
    sm = SparseMatrix.from_dense(a)
    cache = TuningCache(path=tmp_path / "w.json")
    tuner = Tuner(measurer=FakeMeasurer(seed=2), cache=cache)
    first = tuner.tune(sm)
    # pick a measured non-winner candidate as the next caller's incumbent
    other = next(
        m for m in first.measurements if m is not first.best_measurement
    )
    inc_plan = sm.plan(scheme=other.scheme_id.rsplit(".", 2)[0],
                       fmt=other.fmt).scheme
    meas2 = FakeMeasurer(seed=2)
    r2 = Tuner(measurer=meas2, cache=cache).tune(
        SparseMatrix.from_dense(a), baseline=(inc_plan, "xla")
    )
    assert r2.from_cache
    assert meas2.calls == []
    assert r2.baseline.scheme_id == other.scheme_id
    assert r2.baseline.mean_s == pytest.approx(other.mean_s)


def test_tune_cache_bypassed_when_record_lacks_the_incumbent(tmp_path):
    """An incumbent the record never measured cannot be compared from the
    cache — the tuner must re-measure rather than return stale numbers."""
    cache = TuningCache(path=tmp_path / "w.json")
    a = _matrix()
    tuner = Tuner(measurer=FakeMeasurer(), cache=cache)
    tuner.tune(SparseMatrix.from_dense(a))
    sm = SparseMatrix.from_dense(a)
    unmeasured = sm.plan(scheme="2d.variable-sized").scheme  # exotic: never
    meas = FakeMeasurer()                                    # a candidate
    r = Tuner(measurer=meas, cache=cache).tune(
        sm, baseline=(unmeasured, "xla")
    )
    assert not r.from_cache
    assert meas.calls  # actually re-measured


# ------------------------------------------------- engine measure-and-refine


def _tuned_engine(costs=None, **kw):
    tuner = Tuner(measurer=FakeMeasurer(costs=costs or {}))
    return SpmvEngine(cache_capacity=4, tune=True, tuner=tuner, **kw)


def test_engine_refine_swaps_to_forced_winner():
    eng = _tuned_engine(costs={"1d.nnz-rgrn.csr.ppermute": 1e-9})
    a = _matrix()
    eng.register("m", a)
    event = eng.refine("m")
    assert event["swapped"]
    entry = eng.registry.get("m")
    assert entry.cache_key[3] == "1d.nnz-rgrn.csr.ppermute"
    assert entry.tuned
    x = RNG.standard_normal(a.shape[1]).astype(np.float32)
    np.testing.assert_allclose(eng.multiply("m", x), a @ x, rtol=1e-3, atol=1e-4)


def test_engine_refine_keeps_incumbent_inside_margin():
    # every candidate costs the same -> nothing clears the 0.9 margin
    eng = _tuned_engine(costs=None)
    eng._tuner.measurer.costs = {}
    eng._tuner.measurer._fake_time = lambda plan: 1e-3
    eng.register("m", _matrix())
    before = eng.registry.get("m").cache_key
    event = eng.refine("m")
    assert not event["swapped"]
    assert eng.registry.get("m").cache_key == before
    assert eng.registry.get("m").tuned


def test_engine_background_refine_triggers_off_live_traffic():
    eng = _tuned_engine(costs={"1d.nnz-rgrn.csr.ppermute": 1e-9}, tune_after=3)
    a = _matrix()
    eng.register("m", a)
    x = RNG.standard_normal(a.shape[1]).astype(np.float32)
    for _ in range(4):
        eng.multiply("m", x)
    eng.drain_tuning()
    assert eng.tune_events, "no refinement ran"
    assert eng.tune_events[0]["swapped"]
    assert eng.registry.get("m").tuned
    np.testing.assert_allclose(eng.multiply("m", x), a @ x, rtol=1e-3, atol=1e-4)


def test_engine_refine_is_one_shot_per_entry():
    eng = _tuned_engine(tune_after=2)
    a = _matrix()
    eng.register("m", a)
    x = RNG.standard_normal(a.shape[1]).astype(np.float32)
    for _ in range(6):
        eng.multiply("m", x)
    eng.drain_tuning()
    assert len(eng.tune_events) == 1


def test_engine_refine_swap_does_not_evict_other_matrices():
    """At cache capacity, a refinement swap must be net-zero (old plan out,
    winner in) — never pushing a *different* matrix's only executable out."""
    eng = _tuned_engine(costs={"1d.nnz-rgrn.csr.ppermute": 1e-9})
    eng.cache.capacity = 2
    a1, a2 = _matrix("regular"), _matrix("scale-free")
    eng.register("m1", a1)
    eng.register("m2", a2)
    x1 = RNG.standard_normal(a1.shape[1]).astype(np.float32)
    eng.multiply("m1", x1)  # m2 is now the LRU entry
    event = eng.refine("m1")
    assert event["swapped"]
    assert eng.plan_for("m2") is not None, "refine evicted m2's only plan"
    x2 = RNG.standard_normal(a2.shape[1]).astype(np.float32)
    np.testing.assert_allclose(
        eng.multiply("m2", x2), a2 @ x2, rtol=1e-3, atol=1e-4
    )
    assert eng.plan_for("m1") is not None  # old m1 plan evicted, winner in
    assert len(eng.cache) == 2


def test_engine_failing_refinement_does_not_respawn():
    class _Boom:
        def tune(self, *a, **k):
            raise RuntimeError("measurement exploded")

    eng = SpmvEngine(cache_capacity=4, tune=True, tuner=_Boom(), tune_after=2)
    a = _matrix()
    eng.register("m", a)
    x = RNG.standard_normal(a.shape[1]).astype(np.float32)
    for _ in range(6):
        eng.multiply("m", x)
    eng.drain_tuning()
    assert len(eng.tune_events) == 1  # one failed attempt, no respawn storm
    assert "error" in eng.tune_events[0]
    assert eng.registry.get("m").tuned
    np.testing.assert_allclose(eng.multiply("m", x), a @ x, rtol=1e-3, atol=1e-4)


def test_engine_tune_margin_validation():
    with pytest.raises(ValueError):
        SpmvEngine(tune=True, tune_margin=0.0)
    with pytest.raises(ValueError):
        SpmvEngine(tune=True, tune_margin=1.5)


# ----------------------------------------------------------- slow (nightly)


@pytest.mark.slow
def test_tune_end_to_end_real_measurer():
    """The full loop with real timing: the tuned pick must serve correctly
    and must not measure slower than the analytic pick (argmin contract)."""
    a = _matrix("scale-free")
    sm = SparseMatrix.from_dense(a)
    tuner = Tuner(measurer=Measurer(warmup=1, iters=3))
    result = tuner.tune(sm)
    assert result.best_measurement.mean_s <= result.baseline.mean_s
    exe = result.best.compile()
    x = RNG.standard_normal(a.shape[1]).astype(np.float32)
    np.testing.assert_allclose(exe(x), a @ x, rtol=1e-3, atol=1e-4)
    exe.release()


@pytest.mark.slow
def test_engine_refine_real_measurer_multi_device():
    """Long tuner loop on whatever pool exists (nightly runs this with 8
    forced host devices, so distributed candidates are measured too)."""
    eng = SpmvEngine(
        cache_capacity=8,
        tune=True,
        tuner=Tuner(measurer=Measurer(warmup=1, iters=2, trim=0)),
        tune_after=2,
    )
    a = _matrix("regular")
    eng.register("m", a)
    x = RNG.standard_normal(a.shape[1]).astype(np.float32)
    for _ in range(3):
        eng.multiply("m", x)
    eng.drain_tuning(timeout=300.0)
    assert eng.tune_events
    event = eng.tune_events[0]
    assert "error" not in event
    np.testing.assert_allclose(eng.multiply("m", x), a @ x, rtol=1e-3, atol=1e-4)
