#!/usr/bin/env python
"""Benchmark regression gate (CI perf job).

Compares a fresh ``benchmarks/run.py --smoke --json`` output against the
committed ``BENCH_smoke.json`` baseline, row by row (matched on the CSV
``name`` column), and fails when any row's wall-clock regresses by more
than its gate (default ``--threshold`` 2.5x — tiny-shape CPU timings are
dispatch-dominated and noisy across runner generations, so the gate catches
catastrophic regressions like an accidental retrace per call, not 10%
drift).  A row present in the baseline but missing from the current run
also fails: a silently vanished benchmark is exactly the wiring rot the
smoke run exists to catch.  New rows (current-only) are reported but pass —
adding a benchmark must not require a two-step baseline dance.

A baseline row may carry an optional ``"gate_factor"`` field overriding the
global threshold **for that row only** — e.g. the ``serve.cluster.*`` rows
gate at 8x because a multi-process replay's wall-clock folds in process
scheduling and socket round-trips, far noisier than a single-process
kernel loop.  Per-row gates can only be set in the *committed baseline*
(review-gated), never by the current run, so a regression cannot loosen
its own gate.  A present-but-invalid ``gate_factor`` (non-numeric, bool,
zero or negative) fails the run loudly with the offending row named —
a typo'd gate must never silently disable or distort its comparison.

    python tools/check_bench.py --baseline BENCH_smoke.json \
        --current bench_out.json [--threshold 2.5]

Exit status: 0 clean, 1 on regression/missing rows, 2 on unreadable input.
Update the baseline by committing a fresh ``--smoke --json`` output.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> tuple[dict, dict]:
    """({name: us_per_call}, {name: gate_factor}) from a --json document.

    Rows tagged ``"kind": "count"`` (e.g. serve.shed.* shed-by-reason
    counters) carry event counts in the us_per_call slot, not wall-clock —
    they ride in the JSON for trajectory tracking but are excluded here, so
    the regression gate (and its missing-row check) only ever compares
    timings against timings.  ``gate_factor`` is collected per row where
    present (only the baseline's side is ever consulted).
    """
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    out, gates = {}, {}
    for r in rows:
        if r.get("kind") == "count":
            continue
        out[r["name"]] = float(r["us_per_call"])
        gate = r.get("gate_factor")
        if gate is not None:
            # a present-but-broken gate must fail LOUDLY, naming the row:
            # bool would silently coerce (True -> gate 1.0x, flagging every
            # row), and a string/zero/negative gate would either crash with
            # a useless message or disable the comparison it claims to tune
            if isinstance(gate, bool) or not isinstance(gate, (int, float)):
                raise ValueError(
                    f"row {r['name']!r} in {path}: gate_factor must be a "
                    f"positive number, got {gate!r} ({type(gate).__name__})"
                )
            if gate <= 0:
                raise ValueError(
                    f"row {r['name']!r} in {path}: gate_factor must be "
                    f"> 0, got {gate!r}"
                )
            gates[r["name"]] = float(gate)
    return out, gates


def ratio_of(b: float, c: float) -> float:
    """current/baseline with a sound zero-baseline rule: a 0 -> 0 row is
    unchanged (rate-style rows like serve.reject.permille are legitimately
    zero), while 0 -> anything positive is an infinite regression (the
    quantity appeared out of nowhere)."""
    if b > 0:
        return c / b
    return 1.0 if c <= 0 else float("inf")


def compare(base: dict, cur: dict, threshold: float,
            gates: dict = None) -> tuple[list, list, list]:
    """Returns (regressions, missing, new) where regressions are
    (name, base_us, cur_us, ratio) tuples.  ``gates`` maps row names to
    per-row threshold overrides (from the committed baseline)."""
    gates = gates or {}
    regressions = []
    for name in sorted(base.keys() & cur.keys()):
        b, c = base[name], cur[name]
        ratio = ratio_of(b, c)
        if ratio > gates.get(name, threshold):
            regressions.append((name, b, c, ratio))
    missing = sorted(base.keys() - cur.keys())
    new = sorted(cur.keys() - base.keys())
    return regressions, missing, new


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_smoke.json",
                    help="committed baseline JSON")
    ap.add_argument("--current", default="bench_out.json",
                    help="fresh --smoke --json output")
    ap.add_argument("--threshold", type=float, default=2.5,
                    help="fail when current/baseline exceeds this ratio "
                         "(a baseline row's gate_factor overrides it)")
    args = ap.parse_args()

    try:
        base, gates = load_rows(args.baseline)
        cur, _ = load_rows(args.current)  # current-run gates never apply
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"ERROR: unreadable benchmark JSON: {type(e).__name__}: {e}")
        return 2

    regressions, missing, new = compare(base, cur, args.threshold, gates)

    shared = sorted(base.keys() & cur.keys())
    for name in shared:
        ratio = ratio_of(base[name], cur[name])
        gate = gates.get(name, args.threshold)
        flag = " <-- REGRESSION" if ratio > gate else ""
        note = f", gate {gate}x" if name in gates else ""
        print(f"{name}: {base[name]:.1f}us -> {cur[name]:.1f}us "
              f"({ratio:.2f}x{note}){flag}")
    for name in new:
        print(f"{name}: (new row, {cur[name]:.1f}us — no baseline yet)")
    for name in missing:
        print(f"{name}: MISSING from current run (baseline {base[name]:.1f}us)")

    print(f"\n{len(shared)} rows compared against {args.baseline} "
          f"(threshold {args.threshold}x, {len(gates)} per-row gates): "
          f"{len(regressions)} regressions, {len(missing)} missing, "
          f"{len(new)} new")
    if regressions or missing:
        print("FAIL — if intentional, commit a fresh baseline: "
              "PYTHONPATH=src python -m benchmarks.run --smoke "
              "--json BENCH_smoke.json")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
