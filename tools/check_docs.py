#!/usr/bin/env python
"""Documentation link + symbol checker (CI docs job).

Walks README.md and docs/*.md and fails if

  * a relative markdown link ``[text](path)`` points at a file or directory
    that does not exist (anchors and absolute URLs are skipped), or
  * a backticked dotted symbol starting with ``repro.`` does not resolve to
    an importable module / attribute chain, or
  * a symbol exported via ``__all__`` from the serving-facing packages
    (:data:`COVERED_MODULES` — ``repro.serve``, ``repro.obs``,
    ``repro.topo``) is never
    mentioned in any backticked span of the docs corpus: the public surface
    must be documented somewhere a reader can find it.

This keeps the documented snippets from rotting in both directions: a
renamed module breaks the docs job (stale docs), and a new public symbol
without a docs mention breaks it too (undocumented surface) — not a
future reader.

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SYMBOL_RE = re.compile(r"`(repro(?:\.\w+)+)`")

# packages whose entire __all__ surface must appear in the docs corpus
COVERED_MODULES = ("repro.serve", "repro.obs", "repro.topo")


def check_links(md: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def resolve_symbol(dotted: str) -> bool:
    """Import the longest module prefix, then getattr the rest."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_symbols(md: Path) -> list[str]:
    errors = []
    for dotted in sorted(set(SYMBOL_RE.findall(md.read_text()))):
        if not resolve_symbol(dotted):
            errors.append(
                f"{md.relative_to(ROOT)}: unresolvable symbol `{dotted}`"
            )
    return errors


def check_symbol_coverage(corpus: str) -> list[str]:
    """Every ``__all__`` symbol of :data:`COVERED_MODULES` has a docs home.

    A symbol counts as documented when its bare name appears inside any
    code span of the corpus — an inline backtick span or a fenced code
    block both qualify; a prose mention without code formatting does not
    (that is how dead API names linger).  Fenced blocks are cut out before
    the inline scan so their triple backticks cannot shift the pairing of
    the single-backtick spans around them.
    """
    errors = []
    fence = re.compile(r"```.*?```", re.DOTALL)
    blocks = fence.findall(corpus)
    inline = re.findall(r"`[^`]+`", fence.sub("", corpus))
    spans = "\n".join(blocks + inline)
    for modname in COVERED_MODULES:
        mod = importlib.import_module(modname)
        for sym in getattr(mod, "__all__", ()):
            if not re.search(rf"\b{re.escape(sym)}\b", spans):
                errors.append(
                    f"{modname}.{sym} is exported via __all__ but never "
                    "mentioned (backticked) in README.md or docs/*.md"
                )
    return errors


def main() -> int:
    docs = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    missing = [d for d in docs if not d.exists()]
    if missing:
        print(f"missing doc files: {[str(m) for m in missing]}")
        return 1
    errors = []
    n_links = n_syms = 0
    corpus = []
    for md in docs:
        text = md.read_text()
        corpus.append(text)
        n_links += len(LINK_RE.findall(text))
        n_syms += len(set(SYMBOL_RE.findall(text)))
        errors += check_links(md)
        errors += check_symbols(md)
    errors += check_symbol_coverage("\n".join(corpus))
    n_covered = sum(
        len(getattr(importlib.import_module(m), "__all__", ()))
        for m in COVERED_MODULES
    )
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(docs)} files, {n_links} links, "
          f"{n_syms} repro.* symbols, "
          f"{n_covered} __all__ exports from {len(COVERED_MODULES)} "
          f"packages: {'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
