#!/usr/bin/env python
"""Documentation link + symbol checker (CI docs job).

Walks README.md and docs/*.md and fails if

  * a relative markdown link ``[text](path)`` points at a file or directory
    that does not exist (anchors and absolute URLs are skipped), or
  * a backticked dotted symbol starting with ``repro.`` does not resolve to
    an importable module / attribute chain.

This keeps the documented snippets from rotting: a renamed module, a moved
example or a deleted doc breaks the docs job, not a future reader.

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SYMBOL_RE = re.compile(r"`(repro(?:\.\w+)+)`")


def check_links(md: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def resolve_symbol(dotted: str) -> bool:
    """Import the longest module prefix, then getattr the rest."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_symbols(md: Path) -> list[str]:
    errors = []
    for dotted in sorted(set(SYMBOL_RE.findall(md.read_text()))):
        if not resolve_symbol(dotted):
            errors.append(
                f"{md.relative_to(ROOT)}: unresolvable symbol `{dotted}`"
            )
    return errors


def main() -> int:
    docs = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    missing = [d for d in docs if not d.exists()]
    if missing:
        print(f"missing doc files: {[str(m) for m in missing]}")
        return 1
    errors = []
    n_links = n_syms = 0
    for md in docs:
        n_links += len(LINK_RE.findall(md.read_text()))
        n_syms += len(set(SYMBOL_RE.findall(md.read_text())))
        errors += check_links(md)
        errors += check_symbols(md)
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(docs)} files, {n_links} links, "
          f"{n_syms} repro.* symbols: "
          f"{'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
