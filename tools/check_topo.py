#!/usr/bin/env python
"""Compiled-mode topology dry-run gate (CI perf job).

The topo smoke benchmark runs the Pallas kernels in *interpret* mode (CPU
runners), so on its own it cannot prove that a topology-placed 2D plan
still **lowers for real TPUs** — a Mosaic-incompatible op introduced
anywhere under ``repro.topo``'s mesh construction would only surface on
hardware.  This gate closes that hole without a TPU: it plans 2D schemes
on a host-simulated :class:`repro.topo.FakeTopology` with
``impl="pallas", interpret=False`` and AOT cross-lowers each program for
the ``tpu`` platform via ``jax.export`` — the same Mosaic pipeline a real
device run compiles through, minus execution.

Per format:

  * ``bcoo`` / ``bcsr`` (the block formats) are **gated**: they must
    export and their module must contain the Mosaic ``tpu_custom_call``;
    any failure exits 1.
  * ``coo`` / ``csr`` are **known-gap**: their scalar gather kernels do
    not yet clear the Mosaic ``gather`` lowering (tracked in
    docs/kernels.md); a failure prints a warning, an unexpected *success*
    prints a note so the gap list gets updated.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tools/check_topo.py

Exit status: 0 clean, 1 when a gated format fails to lower.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# ^ must precede jax imports (device count locks at first init)

import argparse
import sys

import numpy as np

GATED = ("bcoo", "bcsr")
KNOWN_GAP = ("coo", "csr")


def export_tpu(plan):
    """AOT-lower ``plan``'s shard_map program for the tpu platform.

    Returns the exported module text.  Mirrors ``ExecutionPlan.compile()``
    up to (but not including) device placement: the export runs against
    abstract avals shaped like the placed arrays, so no TPU is needed.
    """
    import jax

    from repro import compat
    from repro.core import distributed as D

    try:
        from jax import export
    except ImportError as e:  # pragma: no cover - jax too old
        raise RuntimeError(f"jax.export unavailable: {e}") from e

    part = plan._partition()
    prog = plan.program(part)
    arrs = D._arrays(part)
    extra = plan._pallas_extra(part)
    if extra:
        arrs.update(extra)
    R, C = part.grid
    avals = {
        k: jax.ShapeDtypeStruct((R, C) + np.asarray(v).shape[1:],
                                np.asarray(v).dtype)
        for k, v in arrs.items()
    }
    x_aval = jax.ShapeDtypeStruct((plan._x_pad(part),), plan.dtype)
    with compat.set_mesh(plan.mesh):
        exported = export.export(jax.jit(prog.jitted),
                                 platforms=("tpu",))(avals, x_aval)
    return exported.mlir_module()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=64)
    ap.add_argument("--cols", type=int, default=128)
    ap.add_argument("--formats", nargs="*", default=list(GATED + KNOWN_GAP),
                    help="container formats to dry-run (default: all four)")
    args = ap.parse_args(argv)

    import jax

    from repro.api import SparseMatrix
    from repro.data.matrices import regular_matrix
    from repro.topo import FakeTopology

    devices = jax.devices()
    if len(devices) < 4:
        print(f"ERROR: need 4 host devices, got {len(devices)} "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        return 1
    topo = FakeTopology.pim_like((2, 2), devices=devices[:4])
    sm = SparseMatrix.from_dense(
        regular_matrix(args.rows, args.cols, 5, seed=0))

    failures = []
    for fmt in args.formats:
        plan = sm.plan(scheme="2d.equally-sized", fmt=fmt, impl="pallas",
                       interpret=False, topology=topo)
        assert plan.topo_assignment is not None, "topology pick missing"
        tag = plan.scheme_id
        try:
            module = export_tpu(plan)
        except Exception as e:  # Mosaic lowering errors are backend-typed
            msg = str(e).splitlines()[0][:120]
            if fmt in KNOWN_GAP:
                print(f"[known-gap] {tag}: tpu export failed as expected "
                      f"({type(e).__name__}: {msg})")
            else:
                print(f"[FAIL] {tag}: tpu export raised "
                      f"{type(e).__name__}: {msg}")
                failures.append(fmt)
            continue
        if "tpu_custom_call" not in module:
            print(f"[FAIL] {tag}: exported module has no tpu_custom_call "
                  "(Pallas kernel fell out of the program)")
            failures.append(fmt)
            continue
        if fmt in KNOWN_GAP:
            print(f"[note] {tag}: known-gap format now exports cleanly — "
                  "move it to the gated list (docs/kernels.md)")
        else:
            order = [getattr(d, "id", i) for i, d in
                     enumerate(plan.mesh.devices.flat)]
            print(f"[ok] {tag}: tpu_custom_call present, "
                  f"device order {order}")

    print(f"\n{len(args.formats)} formats dry-run on {topo.name}: "
          f"{len(failures)} gated failures")
    if failures:
        print("FAIL — a topology-placed block-format plan no longer lowers "
              "for TPU")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
