#!/usr/bin/env python
"""Replay a seeded serving workload and dump its spans as a Chrome trace.

    PYTHONPATH=src python tools/trace_dump.py [--out serve_trace.json]
                                              [--requests N] [--seed S]
                                              [--top K]

Runs a small two-tenant replay against an in-process
:class:`repro.serve.AsyncSpmvService` (same shape as the serve benchmark's
smoke workload), then:

  * writes the tracer's span buffer as Chrome ``chrome://tracing`` JSON —
    load the file at https://ui.perfetto.dev, each request is one timeline
    row decomposed into admit / queue_wait / batch_form / load / kernel /
    retrieve / deliver spans, and
  * prints the ``--top`` slowest requests' phase breakdowns to stdout, so
    one artifact shows the full life of the worst request without leaving
    the terminal.

The span math lives in :mod:`repro.obs.tracing` (:func:`chrome_trace`,
:func:`trace_summary`); this script is only the harness around it.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys


def build_service():
    from repro.data.matrices import regular_matrix, scale_free_matrix
    from repro.engine import SpmvEngine
    from repro.serve import AsyncSpmvService, TenantConfig

    service = AsyncSpmvService(
        SpmvEngine(cache_capacity=8),
        tenants={"tenant-a": TenantConfig(max_pending=128),
                 "tenant-b": TenantConfig(max_pending=128)},
    )
    service.register(None, "social", scale_free_matrix(96, 128, 700, seed=0))
    service.register(None, "mesh", regular_matrix(96, 128, 5, seed=1))
    return service


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", metavar="PATH", default="serve_trace.json",
                    help="Chrome/Perfetto trace JSON output path")
    ap.add_argument("--requests", type=int, default=48,
                    help="replayed trace length")
    ap.add_argument("--seed", type=int, default=21)
    ap.add_argument("--top", type=int, default=3,
                    help="print the K slowest requests' phase breakdowns")
    args = ap.parse_args(argv)

    from repro.obs.tracing import chrome_trace, trace_summary
    from repro.serve import WorkloadSpec, generate_trace, replay

    service = build_service()
    spec = WorkloadSpec(
        names=("social", "mesh"),
        tenants=("tenant-a", "tenant-b"),
        n_requests=args.requests,
        seed=args.seed,
        zipf_alpha=1.2,
        rate_rps=2000.0,
        arrivals="bursty",
        batch_mix={1: 0.85, 4: 0.1, 8: 0.05},
    )

    async def run():
        async with service:
            # warmup pays compilation so the dumped trace shows serving, not
            # the first-touch compile of each batch bucket
            await replay(service, generate_trace(WorkloadSpec(
                names=spec.names, tenants=spec.tenants,
                n_requests=max(16, args.requests // 4), seed=args.seed + 1,
                batch_mix=spec.batch_mix,
            )), time_scale=0.0)
            service.tracer.clear()
            report = await replay(service, generate_trace(spec),
                                  time_scale=0.0)
            return report, service.tracer.spans()

    report, spans = asyncio.run(run())

    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(chrome_trace(spans), fh)
    print(f"wrote {args.out}: {len(spans)} spans from "
          f"{report.completed} completed requests "
          f"(span coverage {report.span_coverage:.3f})")

    summaries = trace_summary(spans)
    worst = sorted(summaries.values(), key=lambda t: t["total_s"],
                   reverse=True)[: args.top]
    for rank, t in enumerate(worst, 1):
        phases = " ".join(
            f"{name}={dur * 1e3:.3f}ms"
            for name, dur in sorted(t["phases"].items(),
                                    key=lambda kv: -kv[1])
        )
        print(f"#{rank} {t['label']}: {t['total_s'] * 1e3:.3f}ms e2e, "
              f"coverage {t['coverage']:.3f}")
        print(f"    {phases}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
