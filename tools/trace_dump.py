#!/usr/bin/env python
"""Replay a seeded serving workload and dump its spans as a Chrome trace —
or merge already-dumped per-worker traces into one cluster timeline.

Replay mode (no ``--trace`` arguments)::

    PYTHONPATH=src python tools/trace_dump.py [--out serve_trace.json]
                                              [--requests N] [--seed S]
                                              [--top K]

Runs a small two-tenant replay against an in-process
:class:`repro.serve.AsyncSpmvService` (same shape as the serve benchmark's
smoke workload), then:

  * writes the tracer's span buffer as Chrome ``chrome://tracing`` JSON —
    load the file at https://ui.perfetto.dev, each request is one timeline
    row decomposed into admit / queue_wait / batch_form / load / kernel /
    retrieve / deliver spans, and
  * prints the ``--top`` slowest requests' phase breakdowns to stdout, so
    one artifact shows the full life of the worst request without leaving
    the terminal.

Merge mode (one or more ``--trace`` arguments)::

    PYTHONPATH=src python tools/trace_dump.py \\
        --trace w0.json --trace w1.json --out cluster_trace.json

Each input file (one Chrome trace document per worker, e.g. the per-worker
dumps a ``serve_replay --workers N`` run leaves behind) becomes one
Perfetto *process* row — ``pid`` = input index, ``process_name`` = the
file's ``--label`` (or its basename) — so an N-worker replay renders as
one cluster timeline.  The merge math lives in
:func:`repro.obs.tracing.merge_chrome_traces`; this script is only the
harness around it.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys


def build_service():
    from repro.data.matrices import regular_matrix, scale_free_matrix
    from repro.engine import SpmvEngine
    from repro.serve import AsyncSpmvService, TenantConfig

    service = AsyncSpmvService(
        SpmvEngine(cache_capacity=8),
        tenants={"tenant-a": TenantConfig(max_pending=128),
                 "tenant-b": TenantConfig(max_pending=128)},
    )
    service.register(None, "social", scale_free_matrix(96, 128, 700, seed=0))
    service.register(None, "mesh", regular_matrix(96, 128, 5, seed=1))
    return service


def merge_traces(paths, labels, out_path: str) -> int:
    """Merge per-worker Chrome trace files into one cluster timeline."""
    from repro.obs.tracing import merge_chrome_traces

    docs = []
    for path in paths:
        with open(path, encoding="utf-8") as fh:
            docs.append(json.load(fh))
    if labels and len(labels) != len(paths):
        print(f"error: {len(labels)} --label for {len(paths)} --trace",
              file=sys.stderr)
        return 2
    if not labels:
        labels = [os.path.splitext(os.path.basename(p))[0] for p in paths]
    merged = merge_chrome_traces(docs, labels=labels)
    with open(out_path, "w", encoding="utf-8") as fh:
        json.dump(merged, fh)
    n_events = len(merged["traceEvents"])
    print(f"wrote {out_path}: {n_events} events merged from "
          f"{len(paths)} trace(s) ({', '.join(labels)})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", metavar="PATH", default="serve_trace.json",
                    help="Chrome/Perfetto trace JSON output path")
    ap.add_argument("--trace", metavar="PATH", action="append", default=[],
                    help="merge mode: an existing per-worker trace JSON "
                         "(repeatable); skips the replay entirely")
    ap.add_argument("--label", metavar="NAME", action="append", default=[],
                    help="merge mode: process name for the matching "
                         "--trace (repeatable; default: file basename)")
    ap.add_argument("--requests", type=int, default=48,
                    help="replayed trace length")
    ap.add_argument("--seed", type=int, default=21)
    ap.add_argument("--top", type=int, default=3,
                    help="print the K slowest requests' phase breakdowns")
    args = ap.parse_args(argv)

    if args.trace:
        return merge_traces(args.trace, args.label, args.out)

    from repro.obs.tracing import chrome_trace, trace_summary
    from repro.serve import WorkloadSpec, generate_trace, replay

    service = build_service()
    spec = WorkloadSpec(
        names=("social", "mesh"),
        tenants=("tenant-a", "tenant-b"),
        n_requests=args.requests,
        seed=args.seed,
        zipf_alpha=1.2,
        rate_rps=2000.0,
        arrivals="bursty",
        batch_mix={1: 0.85, 4: 0.1, 8: 0.05},
    )

    async def run():
        async with service:
            # warmup pays compilation so the dumped trace shows serving, not
            # the first-touch compile of each batch bucket
            await replay(service, generate_trace(WorkloadSpec(
                names=spec.names, tenants=spec.tenants,
                n_requests=max(16, args.requests // 4), seed=args.seed + 1,
                batch_mix=spec.batch_mix,
            )), time_scale=0.0)
            service.tracer.clear()
            report = await replay(service, generate_trace(spec),
                                  time_scale=0.0)
            return report, service.tracer.spans()

    report, spans = asyncio.run(run())

    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(chrome_trace(spans), fh)
    print(f"wrote {args.out}: {len(spans)} spans from "
          f"{report.completed} completed requests "
          f"(span coverage {report.span_coverage:.3f})")

    summaries = trace_summary(spans)
    worst = sorted(summaries.values(), key=lambda t: t["total_s"],
                   reverse=True)[: args.top]
    for rank, t in enumerate(worst, 1):
        phases = " ".join(
            f"{name}={dur * 1e3:.3f}ms"
            for name, dur in sorted(t["phases"].items(),
                                    key=lambda kv: -kv[1])
        )
        print(f"#{rank} {t['label']}: {t['total_s'] * 1e3:.3f}ms e2e, "
              f"coverage {t['coverage']:.3f}")
        print(f"    {phases}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
